"""Jit'd public wrappers for the streamed-weight matmul.

``stream_matmul(x, w, mode=...)``:
  mode="stream"  grid-pipelined K-block streaming (auto double-buffer)
  mode="fifo"    explicit n_buffers-deep prefetch ring (credit semantics)
  mode="pinned"  whole W resident in VMEM for the call (on-chip tier):
                 single K step, W delivered via the grid pipeline once.

The placement plan (core/streaming.plan_vmem_residency) chooses the mode
per weight tensor; ``ops`` is the seam where that decision becomes a
kernel configuration, the way the H2PIPE compiler instantiates either an
on-chip weight buffer or an HBM FIFO chain per layer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.stream_matmul.kernel import (stream_matmul_kernel,
                                                stream_matmul_manual)
from repro.kernels.stream_matmul.ref import stream_matmul_ref


@functools.partial(jax.jit, static_argnames=("mode", "bm", "bk", "bn",
                                             "n_buffers", "interpret"))
def stream_matmul(x, w, *, mode: str = "stream", bm: int = 128,
                  bk: int = 512, bn: int = 128, n_buffers: int = 2,
                  interpret: bool = False):
    if mode == "pinned":
        # whole-W VMEM residency: one K block spanning all of K
        return stream_matmul_kernel(x, w, bm=bm, bk=w.shape[0], bn=bn,
                                    interpret=interpret)
    if mode == "stream":
        return stream_matmul_kernel(x, w, bm=bm, bk=bk, bn=bn,
                                    interpret=interpret)
    if mode == "fifo":
        return stream_matmul_manual(x, w, bm=bm, bk=bk, bn=bn,
                                    n_buffers=n_buffers, interpret=interpret)
    raise ValueError(f"unknown mode {mode!r}")


def vmem_bytes(mode: str, M: int, K: int, N: int, dtype_bytes: int, *,
               bm: int = 128, bk: int = 512, bn: int = 128,
               n_buffers: int = 2) -> int:
    """VMEM working set the call claims — the M20K-cost analogue that the
    placement planner charges per decision (Eq. 1's '-2' term)."""
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    x_b = bm * (K if mode == "fifo" else bk) * dtype_bytes
    if mode == "pinned":
        w_b = K * bn * dtype_bytes
    elif mode == "fifo":
        w_b = n_buffers * bk * bn * dtype_bytes
    else:
        w_b = 2 * bk * bn * dtype_bytes          # pallas double buffer
    o_b = bm * bn * 4
    return x_b + w_b + o_b
