"""Pure-jnp oracle for the streamed-weight matmul."""
from __future__ import annotations

import jax.numpy as jnp


def stream_matmul_ref(x, w):
    out_dtype = jnp.promote_types(x.dtype, w.dtype)
    if out_dtype == jnp.int8:
        out_dtype = jnp.int32
    acc = jnp.dot(x.astype(jnp.float32) if out_dtype != jnp.int32 else x,
                  w.astype(jnp.float32) if out_dtype != jnp.int32 else w,
                  preferred_element_type=(jnp.int32 if out_dtype == jnp.int32
                                          else jnp.float32))
    return acc.astype(out_dtype)
