"""Streamed-weight matmul — the H2PIPE weight path as a Pallas TPU kernel.

The paper keeps compute units fed from HBM by (a) issuing weight reads
hundreds of cycles ahead (the address stream is deterministic), (b) deep
burst-matching + last-stage FIFOs sized from the measured worst-case
latency, and (c) credit-based flow control bounding the in-flight words.
On TPU the same design maps to (DESIGN.md §2):

  burst length      -> K-block depth of each HBM->VMEM DMA (``bk``)
  last-stage FIFO   -> multi-buffered VMEM scratch (``n_buffers`` slots)
  credit counter    -> the bounded in-flight DMA window: a slot's DMA is
                       issued only after its previous occupant is consumed
                       (wait) — exactly "credits returned on dequeue"
  freeze signal     -> the implicit stall of ``.wait()`` when a buffer has
                       not landed — the grid stalls, nothing else does

Two implementations:

``stream_matmul_kernel``  grid-pipelined: BlockSpec index maps stream X and
    W blocks; the Pallas pipeline double-buffers the HBM->VMEM DMAs
    automatically (n_buffers = 2, fixed).

``stream_matmul_manual``  explicit-FIFO: W stays in ``ANY`` (HBM) memory
    space; the kernel issues its own ``pltpu.make_async_copy`` per K-block
    into an ``n_buffers``-deep VMEM scratch ring with per-slot DMA
    semaphores.  ``n_buffers`` is the paper's FIFO-depth knob — benchmarks
    sweep it like Table II sweeps burst length.

Both accumulate over the K grid dimension in scratch — f32 for float
inputs, exact int32 for int8 inputs (the MXU contract) — and support a
``pinned`` mode in the ops wrapper (whole W resident in VMEM: the paper's
on-chip weight buffer).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params


# ---------------------------------------------------------------------------
# grid-pipelined version (Pallas auto double-buffering)
# ---------------------------------------------------------------------------


def _acc_dtype(out_dtype):
    """int8 inputs accumulate exactly in int32 (the MXU contract and the
    bit-identity guarantee for wide fc heads: sums exceed f32's 2^24);
    float inputs accumulate in f32."""
    return jnp.int32 if out_dtype == jnp.int32 else jnp.float32


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=acc_ref.dtype)

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def stream_matmul_kernel(x, w, *, bm: int = 128, bk: int = 512,
                         bn: int = 128, interpret: bool = False):
    """x: [M, K] @ w: [K, N] -> [M, N].  W blocks stream HBM->VMEM once per
    (m-block, k-block) grid step; ``bk`` is the burst-length analogue."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (x.shape, w.shape)
    nm, nk, nn = M // bm, K // bk, N // bn
    out_dtype = jnp.promote_types(x.dtype, w.dtype)
    if out_dtype == jnp.int8:
        out_dtype = jnp.int32
    grid = (nm, nn, nk)
    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), _acc_dtype(out_dtype))],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, w)


# ---------------------------------------------------------------------------
# explicit-FIFO version (manual DMA ring, credit semantics)
# ---------------------------------------------------------------------------


def _mm_manual_kernel(x_ref, w_hbm_ref, o_ref, w_buf, sems, *,
                      nk: int, n_buffers: int, bk: int, bn: int):
    """One (m, n) output block; K-loop with an ``n_buffers``-deep prefetch
    ring over W K-blocks living in HBM.

    Credit discipline: slot s may hold only one outstanding DMA; issuing
    for k requires the consumer to have drained k - n_buffers (same slot) —
    the in-flight window never exceeds n_buffers bursts, so VMEM (the
    paper's FIFO) cannot be overrun and no deadlock is possible.
    """
    n = pl.program_id(1)

    def dma(k, slot):
        return pltpu.make_async_copy(
            w_hbm_ref.at[pl.ds(k * bk, bk), pl.ds(n * bn, bn)],
            w_buf.at[slot], sems.at[slot])

    # warm-up: fill the prefetch window (the paper's "run the address
    # generator hundreds of cycles ahead")
    for s in range(min(n_buffers, nk)):
        dma(s, s).start()

    acc_dtype = _acc_dtype(o_ref.dtype)

    def body(k, acc):
        slot = jax.lax.rem(k, n_buffers)
        dma(k, slot).wait()                            # freeze until landed
        xk = jax.lax.dynamic_slice_in_dim(x_ref[...], k * bk, bk, axis=1)
        acc = acc + jnp.dot(xk, w_buf[slot],
                            preferred_element_type=acc_dtype)
        # dequeue returns the credit: reuse the slot for k + n_buffers
        nxt = k + n_buffers

        @pl.when(nxt < nk)
        def _():
            dma(nxt, slot).start()
        return acc

    acc = jax.lax.fori_loop(
        0, nk, body, jnp.zeros(o_ref.shape, acc_dtype))
    o_ref[...] = acc.astype(o_ref.dtype)


def stream_matmul_manual(x, w, *, bm: int = 128, bk: int = 512,
                         bn: int = 128, n_buffers: int = 2,
                         interpret: bool = False):
    """Explicit prefetch-ring variant; W never enters the grid pipeline —
    it stays in HBM (memory_space=ANY) and the kernel DMAs K-blocks itself.
    ``n_buffers`` == the paper's FIFO depth / credit count."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0
    nm, nk, nn = M // bm, K // bk, N // bn
    out_dtype = jnp.promote_types(x.dtype, w.dtype)
    if out_dtype == jnp.int8:
        out_dtype = jnp.int32
    grid = (nm, nn)
    return pl.pallas_call(
        functools.partial(_mm_manual_kernel, nk=nk, n_buffers=n_buffers,
                          bk=bk, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, K), lambda m, n: (m, 0)),
            pl.BlockSpec(memory_space=pl.ANY),      # W stays in HBM
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((n_buffers, bk, bn), w.dtype),
            pltpu.SemaphoreType.DMA((n_buffers,)),
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
    )(x, w)
