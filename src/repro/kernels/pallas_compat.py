"""Version-portability shim for Pallas TPU.

The Pallas TPU compiler-params class was renamed across jax releases
(``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``), and older
versions accept a plain dict.  Every kernel in ``repro.kernels`` builds its
``compiler_params`` through :func:`tpu_compiler_params` so the kernels
import and run on any jax the container ships.

The second portability axis is *where* kernels run: on a real TPU the
Mosaic path compiles them; everywhere else (CPU CI, dev laptops) they must
execute in interpret mode.  :func:`resolve_interpret` centralises that
decision so callers can pass ``interpret=None`` ("do the right thing for
this backend") while tests keep forcing ``interpret=True`` explicitly.
"""
from __future__ import annotations

import os
from typing import Any, Optional, Sequence

import jax
from jax.experimental.pallas import tpu as pltpu

# The class moved: new jax exposes ``CompilerParams``, older versions only
# ``TPUCompilerParams``.  Oldest versions want a dict under the "mosaic" key.
_PARAMS_CLS = getattr(pltpu, "CompilerParams",
                      getattr(pltpu, "TPUCompilerParams", None))


def tpu_compiler_params(dimension_semantics: Optional[Sequence[str]] = None,
                        **kwargs: Any):
    """Build a ``compiler_params`` value accepted by this jax's pallas_call.

    ``dimension_semantics`` is the per-grid-dim ("parallel" | "arbitrary")
    tuple every repro kernel sets; extra kwargs pass through.
    """
    if dimension_semantics is not None:
        kwargs["dimension_semantics"] = tuple(dimension_semantics)
    if _PARAMS_CLS is not None:
        return _PARAMS_CLS(**kwargs)
    return dict(mosaic=kwargs)          # pre-dataclass jax fallback


def on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:                    # backend probing can raise at import
        return False


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve an ``interpret`` request against the running backend.

    ``True``/``False`` are honoured verbatim; ``None`` means "interpret
    unless a TPU is attached".  ``REPRO_INTERPRET=0/1`` overrides the
    auto-detection (CI sets ``1`` so kernels run on CPU runners).
    """
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get("REPRO_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return not on_tpu()
