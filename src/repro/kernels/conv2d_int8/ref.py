"""Pure-jnp oracle for the int8 conv engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_int8_ref(x, w, *, stride: int = 1, padding: str = "SAME"):
    """x: [B,H,W,C] int8; w: [kh,kw,C,Co] int8 -> int32 [B,H',W',Co]."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.int8), w.astype(jnp.int8),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32)
