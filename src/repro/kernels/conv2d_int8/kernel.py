"""int8 conv2d — the HPIPE layer engine as a Pallas TPU kernel.

HPIPE computes a convolution row-by-row: a line buffer holds the k_h input
rows under the kernel's receptive field, the engine sweeps the full
activation width per cycle group, and weights are broadcast to the tensor
chains.  The TPU mapping (DESIGN.md §2):

  line buffer (k_h rows)   -> VMEM scratch of k_h padded input rows,
                              refilled by an explicit DMA per output row
                              (the sliding window never holds more than
                              k_h rows — activations stay in the fast tier)
  full-width parallelism   -> each grid step computes one whole output row;
                              the W_out dim rides the MXU/VPU lanes
  int8 x int8 -> int32     -> jnp.dot with preferred_element_type=int32
                              (the AI-TB dot chains)

Grid: (B, H_out).  Input is pre-padded in the ops wrapper so the kernel has
no boundary conditionals (stride handled by strided static slices).

Two weight tiers, selected by the placement plan (core/schedule.py):

``_conv_kernel``         pinned: W delivered once into VMEM by the grid
                         pipeline and reused for every output row — the
                         on-chip M20K weight buffer.
``_conv_stream_kernel``  HBM-streamed: W stays in ``ANY`` (HBM) memory
                         space and its (i, j) tap blocks are DMA'd through
                         an ``n_buffers``-deep VMEM ring *once per output
                         row* — Eq. 2's "kernels are re-read once per
                         output line".  The ring is the last-stage FIFO;
                         reusing a slot only after its previous occupant
                         was consumed is the credit discipline of §V-A
                         (same pattern as ``stream_matmul_manual``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params


def _row_slice(rows_buf, i: int, j: int, stride: int, w_out: int):
    """Strided width slice of line-buffer row i: cols j, j+s, ..."""
    c_in = rows_buf.shape[-1]
    return jax.lax.slice(
        rows_buf[i], (j, 0), (j + (w_out - 1) * stride + 1, c_in),
        (stride, 1))                                      # [w_out, C]


def _fill_line_buffer(x_hbm_ref, rows_buf, sem, *, k_h: int, stride: int):
    """DMA the k_h input rows for this (batch, output-row) grid step."""
    b = pl.program_id(0)
    r = pl.program_id(1)
    pltpu.make_async_copy(
        x_hbm_ref.at[b, pl.ds(r * stride, k_h)], rows_buf, sem).start()
    pltpu.make_async_copy(
        x_hbm_ref.at[b, pl.ds(r * stride, k_h)], rows_buf, sem).wait()


def _conv_kernel(x_hbm_ref, w_ref, o_ref, rows_buf, sem, *,
                 k_h: int, k_w: int, stride: int, w_out: int):
    _fill_line_buffer(x_hbm_ref, rows_buf, sem, k_h=k_h, stride=stride)
    acc = jnp.zeros((w_out, o_ref.shape[-1]), jnp.int32)
    for i in range(k_h):
        for j in range(k_w):
            cols = _row_slice(rows_buf, i, j, stride, w_out)
            wij = w_ref[i, j]                             # [C, C_out]
            acc = acc + jnp.dot(cols, wij,
                                preferred_element_type=jnp.int32)
    o_ref[0, 0] = acc


def _conv_stream_kernel(x_hbm_ref, w_hbm_ref, o_ref, rows_buf, w_buf,
                        row_sem, w_sems, *, k_h: int, k_w: int, stride: int,
                        w_out: int, n_buffers: int):
    """HBM-streamed weights: per output row the k_h*k_w weight taps flow
    HBM -> n_buffers-deep VMEM ring -> MACs, double-buffered so tap t+1's
    DMA overlaps tap t's compute."""
    _fill_line_buffer(x_hbm_ref, rows_buf, row_sem, k_h=k_h, stride=stride)

    taps = [(i, j) for i in range(k_h) for j in range(k_w)]
    nb = min(n_buffers, len(taps))

    def dma(t: int):
        i, j = taps[t]
        return pltpu.make_async_copy(
            w_hbm_ref.at[i, j], w_buf.at[t % nb], w_sems.at[t % nb])

    # warm-up: fill the prefetch window (issue the address stream ahead)
    for t in range(nb):
        dma(t).start()

    acc = jnp.zeros((w_out, o_ref.shape[-1]), jnp.int32)
    for t, (i, j) in enumerate(taps):
        dma(t).wait()                        # freeze until the burst lands
        cols = _row_slice(rows_buf, i, j, stride, w_out)
        acc = acc + jnp.dot(cols, w_buf[t % nb],
                            preferred_element_type=jnp.int32)
        if t + nb < len(taps):               # dequeue returns the credit
            dma(t + nb).start()
    o_ref[0, 0] = acc


def _dwconv_kernel(x_hbm_ref, w_ref, o_ref, rows_buf, sem, *,
                   k_h: int, k_w: int, stride: int, w_out: int):
    """Depthwise (grouped, groups == C) variant of ``_conv_kernel``: each
    channel convolves with its own k_h x k_w filter, so the tap MAC is an
    elementwise VPU multiply against a broadcast [1, C] weight row instead
    of an MXU dot — the per-channel tensor chains of a MobileNet engine."""
    _fill_line_buffer(x_hbm_ref, rows_buf, sem, k_h=k_h, stride=stride)
    acc = jnp.zeros((w_out, o_ref.shape[-1]), jnp.int32)
    for i in range(k_h):
        for j in range(k_w):
            cols = _row_slice(rows_buf, i, j, stride, w_out)
            wij = w_ref[i, j]                             # [1, C]
            acc = acc + cols.astype(jnp.int32) * wij.astype(jnp.int32)
    o_ref[0, 0] = acc


def _dwconv_stream_kernel(x_hbm_ref, w_hbm_ref, o_ref, rows_buf, w_buf,
                          row_sem, w_sems, *, k_h: int, k_w: int,
                          stride: int, w_out: int, n_buffers: int):
    """HBM-streamed depthwise: the (i, j) weight rows ([1, C] taps) flow
    through the same n_buffers-deep VMEM ring / credit discipline as
    ``_conv_stream_kernel``, re-read once per output row (Eq. 2)."""
    _fill_line_buffer(x_hbm_ref, rows_buf, row_sem, k_h=k_h, stride=stride)

    taps = [(i, j) for i in range(k_h) for j in range(k_w)]
    nb = min(n_buffers, len(taps))

    def dma(t: int):
        i, j = taps[t]
        return pltpu.make_async_copy(
            w_hbm_ref.at[i, j], w_buf.at[t % nb], w_sems.at[t % nb])

    for t in range(nb):
        dma(t).start()

    acc = jnp.zeros((w_out, o_ref.shape[-1]), jnp.int32)
    for t, (i, j) in enumerate(taps):
        dma(t).wait()
        cols = _row_slice(rows_buf, i, j, stride, w_out)
        acc = acc + cols.astype(jnp.int32) * w_buf[t % nb].astype(jnp.int32)
        if t + nb < len(taps):
            dma(t + nb).start()
    o_ref[0, 0] = acc


def conv2d_int8_kernel(x_padded, w, *, stride: int = 1,
                       stream: bool = False, n_buffers: int = 2,
                       depthwise: bool = False, interpret: bool = False):
    """x_padded: [B, H_pad, W_pad, C] int8 (already SAME-padded);
    w: [k_h, k_w, C, C_out] int8 — or [k_h, k_w, 1, C] HWIO-depthwise when
    ``depthwise=True`` (the [1, C] tap rows broadcast across the output
    width; C_out == C).  Returns [B, H_out, W_out, C_out] int32.

    ``stream=False`` pins W in VMEM for the whole row sweep (on-chip tier);
    ``stream=True`` leaves W in HBM and re-reads it once per output row
    through an ``n_buffers``-deep double-buffer ring (HBM tier).
    """
    B, H_pad, W_pad, C = x_padded.shape
    k_h, k_w, w_cin, w_cout = w.shape
    if depthwise:
        assert w_cin == 1 and C == w_cout, (w.shape, C)
        C_out = C
        body, stream_body = _dwconv_kernel, _dwconv_stream_kernel
        ring_tap = (1, C)                       # one [1, C] tap per slot
    else:
        assert C == w_cin
        C_out = w_cout
        body, stream_body = _conv_kernel, _conv_stream_kernel
        ring_tap = (C, C_out)                   # one [C, C_out] tap per slot
    H_out = (H_pad - k_h) // stride + 1
    W_out = (W_pad - k_w) // stride + 1
    grid = (B, H_out)
    common = dict(k_h=k_h, k_w=k_w, stride=stride, w_out=W_out)
    out_spec = pl.BlockSpec((1, 1, W_out, C_out), lambda b, r: (b, r, 0, 0))
    out_shape = jax.ShapeDtypeStruct((B, H_out, W_out, C_out), jnp.int32)
    line_buffer = pltpu.VMEM((k_h, W_pad, C), jnp.int8)
    compiler_params = tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))

    if not stream:
        return pl.pallas_call(
            functools.partial(body, **common),
            grid=grid,
            in_specs=[
                pl.BlockSpec(memory_space=pl.ANY),  # activations in HBM
                pl.BlockSpec((k_h, k_w, w_cin, w_cout),
                             lambda b, r: (0, 0, 0, 0)),
            ],
            out_specs=out_spec,
            out_shape=out_shape,
            scratch_shapes=[
                line_buffer,
                pltpu.SemaphoreType.DMA,
            ],
            interpret=interpret,
            compiler_params=compiler_params,
        )(x_padded, w)

    nb = min(n_buffers, k_h * k_w)
    return pl.pallas_call(
        functools.partial(stream_body, n_buffers=nb, **common),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),      # activations in HBM
            pl.BlockSpec(memory_space=pl.ANY),      # weights STAY in HBM
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        scratch_shapes=[
            line_buffer,
            pltpu.VMEM((nb,) + ring_tap, jnp.int8),  # the last-stage FIFO
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA((nb,)),
        ],
        interpret=interpret,
        compiler_params=compiler_params,
    )(x_padded, w)
