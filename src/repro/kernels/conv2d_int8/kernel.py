"""int8 conv2d — the HPIPE layer engine as a Pallas TPU kernel.

HPIPE computes a convolution row-by-row: a line buffer holds the k_h input
rows under the kernel's receptive field, the engine sweeps the full
activation width per cycle group, and weights are broadcast to the tensor
chains.  The TPU mapping (DESIGN.md §2):

  line buffer (k_h rows)   -> VMEM scratch of k_h padded input rows,
                              refilled by an explicit DMA per output row
                              (the sliding window never holds more than
                              k_h rows — activations stay in the fast tier)
  full-width parallelism   -> each grid step computes one whole output row;
                              the W_out dim rides the MXU/VPU lanes
  weight broadcast         -> the [k_h*k_w*C, C_out] weight matrix stays in
                              VMEM across the row sweep (pinned tier) —
                              streaming weights belongs to stream_matmul
  int8 x int8 -> int32     -> jnp.dot with preferred_element_type=int32
                              (the AI-TB dot chains)

Grid: (B, H_out).  Input is pre-padded in the ops wrapper so the kernel has
no boundary conditionals (stride handled by strided static slices).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _conv_kernel(x_hbm_ref, w_ref, o_ref, rows_buf, sem, *,
                 k_h: int, k_w: int, stride: int, w_out: int):
    b = pl.program_id(0)
    r = pl.program_id(1)

    # line buffer refill: DMA the k_h input rows for this output row
    pltpu.make_async_copy(
        x_hbm_ref.at[b, pl.ds(r * stride, k_h)], rows_buf, sem).start()
    pltpu.make_async_copy(
        x_hbm_ref.at[b, pl.ds(r * stride, k_h)], rows_buf, sem).wait()

    c_in = rows_buf.shape[-1]
    acc = jnp.zeros((w_out, o_ref.shape[-1]), jnp.int32)
    for i in range(k_h):
        for j in range(k_w):
            # strided width slice: columns j, j+s, ..., j+(w_out-1)s
            cols = jax.lax.slice(
                rows_buf[i], (j, 0), (j + (w_out - 1) * stride + 1, c_in),
                (stride, 1))                                  # [w_out, C]
            wij = w_ref[i, j]                                 # [C, C_out]
            acc = acc + jnp.dot(cols, wij,
                                preferred_element_type=jnp.int32)
    o_ref[0, 0] = acc


def conv2d_int8_kernel(x_padded, w, *, stride: int = 1,
                       interpret: bool = False):
    """x_padded: [B, H_pad, W_pad, C] int8 (already SAME-padded);
    w: [k_h, k_w, C, C_out] int8.  Returns [B, H_out, W_out, C_out] int32.
    """
    B, H_pad, W_pad, C = x_padded.shape
    k_h, k_w, C2, C_out = w.shape
    assert C == C2
    H_out = (H_pad - k_h) // stride + 1
    W_out = (W_pad - k_w) // stride + 1
    grid = (B, H_out)
    return pl.pallas_call(
        functools.partial(_conv_kernel, k_h=k_h, k_w=k_w, stride=stride,
                          w_out=W_out),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),      # activations in HBM
            pl.BlockSpec((k_h, k_w, C, C_out), lambda b, r: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, W_out, C_out), lambda b, r: (b, r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H_out, W_out, C_out), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((k_h, W_pad, C), jnp.int8),     # the line buffer
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(x_padded, w)
