"""Jit'd wrapper: SAME padding + requantization around the Pallas conv."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.conv2d_int8.kernel import conv2d_int8_kernel
from repro.kernels.conv2d_int8.ref import conv2d_int8_ref


def _same_pad(x, k_h, k_w, stride):
    B, H, W, C = x.shape
    out_h = -(-H // stride)
    out_w = -(-W // stride)
    pad_h = max((out_h - 1) * stride + k_h - H, 0)
    pad_w = max((out_w - 1) * stride + k_w - W, 0)
    return jnp.pad(x, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                       (pad_w // 2, pad_w - pad_w // 2), (0, 0)))


@functools.partial(jax.jit, static_argnames=("stride", "interpret"))
def conv2d_int8(x, w, *, stride: int = 1, interpret: bool = False):
    """SAME conv, int8 in / int32 out, via the line-buffer Pallas kernel."""
    k_h, k_w = w.shape[:2]
    xp = _same_pad(x, k_h, k_w, stride)
    return conv2d_int8_kernel(xp, w, stride=stride, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("stride", "interpret"))
def conv2d_int8_requant(x, w, w_scale, bias, act_scale: float = 0.05, *,
                        stride: int = 1, relu: bool = True,
                        interpret: bool = False):
    """Full HPIPE layer engine: conv + per-channel dequant + bias + relu +
    requantize to int8 for the next engine (models/cnn.py contract)."""
    y = conv2d_int8(x, w, stride=stride, interpret=interpret)
    y = y.astype(jnp.float32) * (w_scale * act_scale) + bias
    if relu:
        y = jax.nn.relu(y)
    return jnp.clip(jnp.round(y / act_scale), -127, 127).astype(jnp.int8)
