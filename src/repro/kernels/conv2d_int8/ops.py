"""Jit'd wrapper: SAME padding + requantization around the Pallas conv.

``stream=True`` selects the HBM-streamed weight path (W re-read once per
output row through a double-buffered VMEM ring); the placement plan
(core/schedule.py) flips that switch per layer, the way the H2PIPE
compiler instantiates either an on-chip weight buffer or an HBM FIFO
chain per layer engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.conv2d_int8.kernel import conv2d_int8_kernel
from repro.kernels.conv2d_int8.ref import conv2d_int8_ref
from repro.kernels.quant import requant_epilogue


def same_padded_width(n: int, k: int, stride: int) -> int:
    """Padded extent of one spatial dim under this module's SAME padding.
    The single source of truth for the kernel's line-buffer geometry —
    ``_same_pad`` below and the compile-time VMEM accounting
    (``repro.compiler.engines``) both derive from it, so they cannot
    desynchronize."""
    out = -(-n // stride)
    return n + max((out - 1) * stride + k - n, 0)


def _same_pad(x, k_h, k_w, stride):
    B, H, W, C = x.shape
    pad_h = same_padded_width(H, k_h, stride) - H
    pad_w = same_padded_width(W, k_w, stride) - W
    return jnp.pad(x, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                       (pad_w // 2, pad_w - pad_w // 2), (0, 0)))


@functools.partial(jax.jit, static_argnames=("stride", "stream", "n_buffers",
                                             "depthwise", "interpret"))
def conv2d_int8(x, w, *, stride: int = 1, stream: bool = False,
                n_buffers: int = 2, depthwise: bool = False,
                interpret: bool = False):
    """SAME conv, int8 in / int32 out, via the line-buffer Pallas kernel.

    ``depthwise=True`` selects the grouped (groups == C) engine for
    HWIO-depthwise weights ``[k_h, k_w, 1, C]`` — the MobileNet dwconv
    path, with the same pinned/streamed weight tiers as the dense conv.
    """
    k_h, k_w = w.shape[:2]
    xp = _same_pad(x, k_h, k_w, stride)
    return conv2d_int8_kernel(xp, w, stride=stride, stream=stream,
                              n_buffers=n_buffers, depthwise=depthwise,
                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=("act_scale", "stride", "relu",
                                             "stream", "n_buffers",
                                             "interpret"))
def conv2d_int8_requant(x, w, w_scale, bias, act_scale: float = 0.05, *,
                        stride: int = 1, relu: bool = True,
                        stream: bool = False, n_buffers: int = 2,
                        interpret: bool = False):
    """Full HPIPE layer engine: conv + per-channel dequant + bias + relu +
    requantize to int8 for the next engine (models/cnn.py contract)."""
    y = conv2d_int8(x, w, stride=stride, stream=stream, n_buffers=n_buffers,
                    interpret=interpret)
    y_q, _ = requant_epilogue(y, w_scale, bias, act_scale=act_scale,
                              relu=relu)
    return y_q
