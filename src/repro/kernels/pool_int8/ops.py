"""Jit'd wrappers: SAME padding around the Pallas pooling kernels.

Padding geometry comes from the conv ops' ``same_padded_width`` — the
single source of truth the compile-time VMEM accounting
(``repro.compiler.engines``) also derives line-buffer sizes from, so
allocation and execution cannot drift apart.  Maxpool pads with int8
-128 (the identity of max; SAME windows always contain at least one
real element, so padding never wins — equivalent to the reference's
+inf-under-min float padding).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.conv2d_int8.ops import same_padded_width
from repro.kernels.pool_int8.kernel import (global_avgpool_int8_kernel,
                                            maxpool_int8_kernel)


@functools.partial(jax.jit, static_argnames=("k", "stride", "interpret"))
def maxpool_int8(x, *, k: int, stride: int, interpret: bool = False):
    """SAME maxpool, int8 in / int8 out, via the line-buffer Pallas
    kernel.  x: [B, H, W, C] -> [B, ceil(H/s), ceil(W/s), C]."""
    B, H, W, C = x.shape
    pad_h = same_padded_width(H, k, stride) - H
    pad_w = same_padded_width(W, k, stride) - W
    xp = jnp.pad(x, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                     (pad_w // 2, pad_w - pad_w // 2), (0, 0)),
                 constant_values=jnp.int8(-128))
    return maxpool_int8_kernel(xp, k_h=k, k_w=k, stride=stride,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("act_scale", "interpret"))
def global_avgpool_int8(x, *, act_scale: float = 0.05,
                        interpret: bool = False):
    """Global average pool + activation requantization, int8 in/out.
    x: [B, H, W, C] -> [B, 1, 1, C]."""
    return global_avgpool_int8_kernel(x, act_scale=act_scale,
                                      interpret=interpret)
