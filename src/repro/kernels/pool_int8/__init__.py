from repro.kernels.pool_int8.ops import (global_avgpool_int8,  # noqa: F401
                                         maxpool_int8)
from repro.kernels.pool_int8.ref import (global_avgpool_int8_ref,  # noqa: F401
                                         maxpool_int8_ref)
