"""Pooling topology nodes as Pallas TPU kernels.

H2PIPE emits a hardware engine for every CNN graph node — pooling
included: a maxpool engine is a line buffer plus comparator trees, a
global-average-pool engine is a per-channel accumulator bank.  The TPU
mapping follows the conv engine (``kernels/conv2d_int8``):

``_maxpool_kernel``   grid (B, H_out); a VMEM line buffer holds the k_h
                      input rows under the window (DMA'd per output row,
                      the same sliding-window discipline as the conv line
                      buffer), and the k_h x k_w taps reduce with
                      ``jnp.maximum`` on the VPU — comparators, no MACs,
                      no weights, no Eq. 2 traffic.
``_gap_kernel``       grid (B,); the (small, end-of-net) spatial map sits
                      in VMEM, channels accumulate in int32 (exact — the
                      sums fit f32's integer range, so the requantized
                      mean is bit-identical to the float32 reference),
                      then the model's activation quantization emits the
                      1x1 int8 map.

Inputs are pre-padded by the ops wrapper (maxpool pads with int8 -128,
the identity of max — the float reference pads with +inf under min; both
can never win), so kernels have no boundary conditionals.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params


def _row_slice(rows_buf, i: int, j: int, stride: int, w_out: int):
    """Strided width slice of line-buffer row i: cols j, j+s, ..."""
    c = rows_buf.shape[-1]
    return jax.lax.slice(
        rows_buf[i], (j, 0), (j + (w_out - 1) * stride + 1, c),
        (stride, 1))                                      # [w_out, C]


def _maxpool_kernel(x_hbm_ref, o_ref, rows_buf, sem, *,
                    k_h: int, k_w: int, stride: int, w_out: int):
    b = pl.program_id(0)
    r = pl.program_id(1)
    cp = pltpu.make_async_copy(
        x_hbm_ref.at[b, pl.ds(r * stride, k_h)], rows_buf, sem)
    cp.start()
    cp.wait()
    acc = jnp.full((w_out, o_ref.shape[-1]), -128, jnp.int8)
    for i in range(k_h):
        for j in range(k_w):
            acc = jnp.maximum(acc, _row_slice(rows_buf, i, j, stride, w_out))
    o_ref[0, 0] = acc


def maxpool_int8_kernel(x_padded, *, k_h: int, k_w: int, stride: int,
                        interpret: bool = False):
    """x_padded: [B, H_pad, W_pad, C] int8 (already SAME-padded with -128).
    Returns [B, H_out, W_out, C] int8."""
    B, H_pad, W_pad, C = x_padded.shape
    H_out = (H_pad - k_h) // stride + 1
    W_out = (W_pad - k_w) // stride + 1
    return pl.pallas_call(
        functools.partial(_maxpool_kernel, k_h=k_h, k_w=k_w, stride=stride,
                          w_out=W_out),
        grid=(B, H_out),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],   # activations in HBM
        out_specs=pl.BlockSpec((1, 1, W_out, C), lambda b, r: (b, r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H_out, W_out, C), jnp.int8),
        scratch_shapes=[
            pltpu.VMEM((k_h, W_pad, C), jnp.int8),      # the line buffer
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(x_padded)


def _gap_kernel(x_ref, o_ref, *, hw: int, act_scale: float):
    s = jnp.sum(x_ref[0].astype(jnp.int32), axis=(0, 1))        # [C] exact
    m = s.astype(jnp.float32) / jnp.float32(hw)   # mean = sum / count, as
    o_ref[0, 0, 0] = jnp.clip(jnp.round(m / act_scale),   # jnp.mean divides
                              -127, 127).astype(jnp.int8)


def global_avgpool_int8_kernel(x, *, act_scale: float = 0.05,
                               interpret: bool = False):
    """x: [B, H, W, C] int8 -> [B, 1, 1, C] int8 (requantized mean).

    The int32 channel sums are exact and fit f32's integer range, and the
    kernel divides by the count exactly as ``jnp.mean`` does — so the
    requantized mean matches the float32 reference bit for bit
    (differential-tested across shapes)."""
    B, H, W, C = x.shape
    return pl.pallas_call(
        functools.partial(_gap_kernel, hw=H * W, act_scale=act_scale),
        grid=(B,),
        in_specs=[pl.BlockSpec((1, H, W, C), lambda b: (b, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, 1, C), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1, 1, C), jnp.int8),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
    )(x)
