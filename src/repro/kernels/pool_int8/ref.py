"""jnp reference semantics for the pooling topology nodes.

These are the EXACT formulas the model's forward pass used when pooling
was still implicit wiring inside ``cnn_forward`` (pre topology-node
migration), kept verbatim so promoting the ops to engines changes where
they run, never a single output bit:

  * maxpool: max over a SAME-padded k x k window — computed as
    ``-reduce_window(-x, min)`` in float32 with +inf padding, exactly the
    old stem-pool expression (padding can never win a max);
  * global average pool: float32 mean over the spatial map, then the
    model's activation quantization (divide by act_scale, round to
    nearest-even, clip) back to int8.

The Pallas kernels in ``kernel.py`` are differential-tested bit-exact
against these (tests/test_topology_engines.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("k", "stride"))
def maxpool_int8_ref(x, *, k: int, stride: int):
    """x: [B, H, W, C] int8 -> [B, ceil(H/s), ceil(W/s), C] int8."""
    return -jax.lax.reduce_window(
        -x.astype(jnp.float32), jnp.inf, jax.lax.min,
        (1, k, k, 1), (1, stride, stride, 1), "SAME").astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("act_scale",))
def global_avgpool_int8_ref(x, *, act_scale: float = 0.05):
    """x: [B, H, W, C] int8 -> [B, 1, 1, C] int8 (requantized mean)."""
    m = jnp.mean(x.astype(jnp.float32), axis=(1, 2), keepdims=True)
    return jnp.clip(jnp.round(m / act_scale), -127, 127).astype(jnp.int8)
