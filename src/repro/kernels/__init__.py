"""Pallas TPU kernels (validated with interpret=True on CPU).

stream_matmul    the paper's weight path: HBM-resident weights streamed
                 through a bounded VMEM prefetch ring (burst/FIFO/credits)
conv2d_int8      HPIPE layer engine: line-buffer row conv, int8 MXU dots
pool_int8        the pooling topology engines: line-buffer maxpool
                 (comparator trees) and global-average-pool (int32
                 channel accumulators + activation requantizer)
flash_attention  blockwise online-softmax attention (causal / window /
                 softcap / GQA)
"""
from repro.kernels.stream_matmul.ops import stream_matmul
from repro.kernels.conv2d_int8.ops import conv2d_int8, conv2d_int8_requant
from repro.kernels.pool_int8.ops import global_avgpool_int8, maxpool_int8
from repro.kernels.flash_attention.ops import flash_attention

__all__ = ["stream_matmul", "conv2d_int8", "conv2d_int8_requant",
           "maxpool_int8", "global_avgpool_int8", "flash_attention"]
